"""repro.analysis: one seeded violation per lint family (the CI gate must
be able to fail), clean passes on the real compiled programs, and the
plan.lint()/analyze() surface on snapshot-segmented and sharded programs."""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import analysis
from repro.analysis import (
    Baseline,
    Finding,
    Suppression,
    collective_lint,
    donation_lint,
    precision_lint,
    retrace_hazard_lint,
    scatter_race_lint_schedule,
    transfer_lint,
    transfer_lint_jaxpr,
)
from repro.sparse.generators import random_sparse_tensor
from repro.sparse.layout import build_mode_layout
from repro.tucker import SnapshotSpec, TuckerSpec
from repro.tucker.planning import TuckerPlan

SHAPE, RANKS = (12, 10, 8), (3, 3, 2)


@pytest.fixture(scope="module")
def coo():
    return random_sparse_tensor(SHAPE, 0.08, seed=0)


@pytest.fixture(scope="module")
def xla_plan():
    return TuckerPlan(
        TuckerSpec(
            shape=SHAPE, ranks=RANKS, method="gram", engine="xla", n_iter=3
        )
    )


@pytest.fixture(scope="module")
def xla_lowered(xla_plan, coo):
    return xla_plan.lower_hlo(coo)


# ---------------------------------------------------------------------------
# seeded violations: every family must be able to fire, and fire precisely.
# ---------------------------------------------------------------------------

# a trip-4 sweep loop with a host outfeed smuggled into the body — the
# canonical "second transfer" violation.
_TRANSFER_HLO = textwrap.dedent(
    """\
    HloModule bad_transfer

    %body.1 (p.2: (f32[8], token[])) -> (f32[8], token[]) {
      %p.2 = (f32[8]{0}, token[]) parameter(0)
      %gte.2 = f32[8]{0} get-tuple-element((f32[8]{0}, token[]) %p.2), index=0
      %tok.2 = token[] get-tuple-element((f32[8]{0}, token[]) %p.2), index=1
      %out.2 = token[] outfeed(f32[8]{0} %gte.2, token[] %tok.2)
      ROOT %tuple.2 = (f32[8]{0}, token[]) tuple(f32[8]{0} %gte.2, token[] %out.2)
    }

    %cond.1 (p.3: (f32[8], token[])) -> pred[] {
      %p.3 = (f32[8]{0}, token[]) parameter(0)
      ROOT %c.3 = pred[] constant(false)
    }

    ENTRY %main.1 (a.1: f32[8]) -> f32[8] {
      %a.1 = f32[8]{0} parameter(0)
      %tok.1 = token[] after-all()
      %tuple.1 = (f32[8]{0}, token[]) tuple(f32[8]{0} %a.1, token[] %tok.1)
      %while.1 = (f32[8]{0}, token[]) while((f32[8]{0}, token[]) %tuple.1), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"4"}}
      ROOT %gte.1 = f32[8]{0} get-tuple-element((f32[8]{0}, token[]) %while.1), index=0
    }
    """
)


def test_transfer_lint_seeded_outfeed():
    findings = transfer_lint(_TRANSFER_HLO, where="cell")
    assert len(findings) == 1
    (f,) = findings
    assert f.check == "transfer" and f.severity == "error"
    assert "outfeed" in f.message and "x4" in f.message


def test_transfer_lint_seeded_callback():
    text = _TRANSFER_HLO.replace(
        "%out.2 = token[] outfeed(f32[8]{0} %gte.2, token[] %tok.2)",
        '%out.2 = token[] custom-call(f32[8]{0} %gte.2), '
        'custom_call_target="xla_python_cpu_callback", '
        "custom_call_has_side_effect=true",
    )
    findings = transfer_lint(text, where="cell")
    assert len(findings) == 1
    assert "custom-call" in findings[0].message


def test_transfer_lint_jaxpr_seeded():
    def leaky(x):
        y = jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct(x.shape, x.dtype), x
        )
        return y * 2.0

    closed = jax.make_jaxpr(leaky)(jnp.ones(3))
    findings = transfer_lint_jaxpr(closed, where="cell")
    assert len(findings) == 1
    assert "callback" in findings[0].message

    clean = jax.make_jaxpr(lambda x: x * 2.0)(jnp.ones(3))
    assert transfer_lint_jaxpr(clean, where="cell") == []


def test_donation_lint_seeded_undonated_carry(xla_lowered):
    text, meta = xla_lowered
    # claim one more donated factor than the executable aliases: exactly
    # that parameter must be reported.
    bogus = tuple(meta["donated_params"]) + (17,)
    findings = donation_lint(text, donated_params=bogus, where="cell")
    assert len(findings) == 1
    assert findings[0].check == "donation"
    assert "parameter 17" in findings[0].message


_BF16_ACC_HLO = textwrap.dedent(
    """\
    ENTRY %main.1 (a.1: bf16[16,16], b.1: bf16[16,16]) -> f32[16,16] {
      %a.1 = bf16[16,16]{1,0} parameter(0)
      %b.1 = bf16[16,16]{1,0} parameter(1)
      %dot.1 = bf16[16,16]{1,0} dot(bf16[16,16]{1,0} %a.1, bf16[16,16]{1,0} %b.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      ROOT %convert.1 = f32[16,16]{1,0} convert(bf16[16,16]{1,0} %dot.1)
    }
    """
)


def test_precision_lint_seeded_bf16_accumulator():
    findings = precision_lint(
        _BF16_ACC_HLO, precision="bf16_fp32acc", where="cell"
    )
    assert len(findings) == 1
    assert findings[0].check == "precision"
    assert "'dot'" in findings[0].message

    # the same dot accumulating to f32 from bf16 operands is the contract
    # working as intended.
    good = _BF16_ACC_HLO.replace(
        "%dot.1 = bf16[16,16]{1,0} dot", "%dot.1 = f32[16,16]{1,0} dot"
    ).replace(
        "ROOT %convert.1 = f32[16,16]{1,0} convert(bf16[16,16]{1,0} %dot.1)",
        "ROOT %convert.1 = f32[16,16]{1,0} convert(f32[16,16]{1,0} %dot.1)",
    )
    assert precision_lint(good, precision="bf16_fp32acc", where="cell") == []


def test_precision_lint_fp32_program_rejects_bf16():
    findings = precision_lint(_BF16_ACC_HLO, precision="fp32", where="cell")
    assert len(findings) == 1
    assert "fp32-precision program" in findings[0].message


_UNSHARDED_COLLECTIVE_HLO = textwrap.dedent(
    """\
    %sum.1 (x.2: f32[], y.2: f32[]) -> f32[] {
      %x.2 = f32[] parameter(0)
      %y.2 = f32[] parameter(1)
      ROOT %add.2 = f32[] add(f32[] %x.2, f32[] %y.2)
    }

    ENTRY %main.1 (a.1: f32[12,6]) -> f32[12,6] {
      %a.1 = f32[12,6]{1,0} parameter(0)
      ROOT %ar.1 = f32[12,6]{1,0} all-reduce(f32[12,6]{1,0} %a.1), replica_groups={}, to_apply=%sum.1
    }
    """
)


def test_collective_lint_seeded_unsharded():
    findings = collective_lint(
        _UNSHARDED_COLLECTIVE_HLO, sharded=False, where="cell"
    )
    assert len(findings) == 1
    assert findings[0].check == "collective"
    assert "unsharded" in findings[0].message


def test_collective_lint_seeded_wrong_count_and_bytes():
    # one 288-byte psum in an unlooped program, against a 3-mode 2-sweep
    # contract: the mode-bytes check passes (288 IS mode 0's unfolding)
    # but count (1 != 6) and total bytes must both fire.
    findings = collective_lint(
        _UNSHARDED_COLLECTIVE_HLO,
        sharded=True,
        shape=SHAPE,
        ranks=RANKS,
        n_sweeps=2,
        where="cell",
    )
    msgs = "\n".join(f.message for f in findings)
    assert len(findings) == 2
    assert "expected exactly 6" in msgs
    assert "psum_bytes_per_sweep predicts" in msgs

    # a payload that is NO mode's unfolding also trips the shape check.
    bad = _UNSHARDED_COLLECTIVE_HLO.replace("f32[12,6]", "f32[12,7]")
    findings = collective_lint(
        bad, sharded=True, shape=SHAPE, ranks=RANKS, n_sweeps=2, where="cell"
    )
    assert any("no mode's partial unfolding" in f.message for f in findings)


def test_retrace_hazard_lint_seeded():
    @dataclasses.dataclass(frozen=True)
    class NanKey:  # accepts NaN: cache-defeating
        tol: float = 0.0

    findings = retrace_hazard_lint(
        classes=(NanKey,), templates=(NanKey(),), where="t"
    )
    assert len(findings) == 1
    assert findings[0].check == "retrace-hazard"
    assert "accepts NaN" in findings[0].message

    @dataclasses.dataclass(frozen=True, eq=True)
    class ListKey:
        items: list = dataclasses.field(default_factory=list)

    findings = retrace_hazard_lint(
        classes=(ListKey,), templates=(), where="t"
    )
    # the mutable annotation alone must be caught statically (frozen=True
    # list-field instances are unhashable too, but the template probe
    # can't even construct a hashable one).
    assert any("mutable container" in f.message for f in findings)

    @dataclasses.dataclass
    class Unfrozen:
        n: int = 1

    findings = retrace_hazard_lint(
        classes=(Unfrozen,), templates=(), where="t"
    )
    assert any("not frozen" in f.message for f in findings)
    assert any("unhashable" in f.message for f in findings)


def test_retrace_hazard_lint_nan_template():
    @dataclasses.dataclass(frozen=True)
    class Key:
        tol: float

    findings = retrace_hazard_lint(
        classes=(), templates=(Key(tol=float("nan")),), where="t"
    )
    assert any("NaN-valued member" in f.message for f in findings)


def test_retrace_hazard_lint_repo_specs_clean():
    assert retrace_hazard_lint() == []


def test_scatter_race_lint_seeded(coo):
    lay = build_mode_layout(coo, 0, bn=8, bi=4)
    rows = np.asarray(coo.indices)[:, 0]
    assert scatter_race_lint_schedule(lay, rows, where="m0") == []

    # corrupt one valid slot's rel_row: its one-hot write now lands in
    # another block's row window — exactly one cross-block race finding.
    rel = np.array(lay.rel_row)
    slot = int(np.argmax(np.asarray(lay.valid) > 0))
    rel[slot] = (rel[slot] + 1) % lay.bi
    bad = lay._replace(rel_row=rel)
    findings = scatter_race_lint_schedule(bad, rows, where="m0")
    assert len(findings) == 1
    assert findings[0].check == "scatter-race"
    assert "write race" in findings[0].message or "clobber" in findings[0].message

    # drop a first-flag: the stale-accumulator hazard (and the derived
    # last-flags disagree too).
    first = np.array(lay.first)
    if first.sum() > 1:
        first[np.flatnonzero(first)[1]] = 0
        bad = lay._replace(first=first)
        findings = scatter_race_lint_schedule(bad, rows, where="m0")
        assert any("not zeroed on group entry" in f.message for f in findings)

    # a dropped nonzero: no longer a permutation.
    order = np.array(lay.order)
    v = np.flatnonzero(np.asarray(lay.valid) > 0)
    order[v[0]] = order[v[1]]
    bad = lay._replace(order=order)
    findings = scatter_race_lint_schedule(bad, rows, where="m0")
    assert any("not a permutation" in f.message for f in findings)


# ---------------------------------------------------------------------------
# clean passes + the plan surface
# ---------------------------------------------------------------------------


def test_xla_scan_plan_lints_clean(xla_plan, coo):
    assert xla_plan.lint(coo) == []


def test_pallas_plan_lints_clean(coo):
    plan = TuckerPlan(
        TuckerSpec(
            shape=SHAPE, ranks=RANKS, method="gram", engine="pallas", n_iter=2
        )
    )
    assert plan.lint(coo) == []


def test_snapshot_segment_plan_lint_and_analyze(coo, tmp_path):
    plan = TuckerPlan(
        TuckerSpec(
            shape=SHAPE, ranks=RANKS, method="gram", engine="xla", n_iter=5,
            snapshot=SnapshotSpec(every_n_sweeps=2, directory=str(tmp_path)),
        )
    )
    assert plan.lint(coo) == []
    a = plan.analyze(coo)
    assert a["program"] == "segment"
    assert a["n_sweeps_traced"] == 2
    assert a["dot_flops"] > 0
    # the segment program does NOT donate factors (the host spills the
    # carry right after dispatch) — the linter must not demand aliases.
    text, meta = plan.lower_hlo(coo)
    assert meta["donated_params"] == ()


def test_batched_plan_lints_clean(xla_plan):
    """The vmapped batch() program holds the same transfer/precision
    contracts as the per-tensor pipeline, and donates nothing (caller-owned
    member/key buffers)."""
    coos = [
        random_sparse_tensor(SHAPE, 0.06 * (1 + i), seed=40 + i)
        for i in range(3)
    ]
    assert xla_plan.lint_batch(coos) == []
    text, meta = xla_plan.lower_batch_hlo(coos)
    assert meta["kind"] == "batched"
    assert meta["batch"] == 3
    assert meta["donated_params"] == ()
    # mixed-nnz members lower at the padded batch max
    assert meta["padded_nnz"] == max(c.nnz for c in coos)


def test_batched_lint_rejects_fallback_plans(coo):
    plan = TuckerPlan(
        TuckerSpec(
            shape=SHAPE, ranks=RANKS, method="gram", engine="pallas", n_iter=2
        )
    )
    with pytest.raises(ValueError, match="sequential fallback"):
        plan.lower_batch_hlo([coo, coo])


def test_batched_cell_in_default_matrix():
    cells = {c.name: c for c in analysis.default_matrix()}
    assert "xla/batched/fp32" in cells
    assert cells["xla/batched/fp32"].batch > 0


def test_python_pipeline_has_no_program(coo):
    plan = TuckerPlan(
        TuckerSpec(
            shape=SHAPE, ranks=RANKS, method="gram", engine="xla",
            pipeline="python",
        )
    )
    with pytest.raises(ValueError, match="no single compiled program"):
        plan.lower_hlo(coo)


def test_baseline_suppression_roundtrip(tmp_path):
    f1 = Finding("transfer", "error", "cell/comp", "an outfeed happened")
    f2 = Finding("donation", "error", "cell/param2", "donation dropped")
    base = Baseline(
        suppressions=[
            Suppression(
                check="transfer", where="cell/*", match="outfeed",
                reason="known CPU-backend artifact",
            )
        ]
    )
    kept, suppressed = base.filter([f1, f2])
    assert kept == [f2] and suppressed == [f1]

    path = tmp_path / "baseline.json"
    base.save(str(path))
    reloaded = Baseline.load(str(path))
    assert reloaded.suppressions == base.suppressions
    kept, suppressed = reloaded.filter([f1, f2])
    assert kept == [f2] and suppressed == [f1]


def test_finding_validation():
    with pytest.raises(ValueError, match="unknown check"):
        Finding("nonsense", "error", "x", "y")
    with pytest.raises(ValueError, match="unknown severity"):
        Finding("transfer", "fatal", "x", "y")


def test_cli_single_cell(tmp_path):
    from repro.analysis.__main__ import main

    out = tmp_path / "report.json"
    rc = main(["--cell", "xla/scan/fp32", "--json", str(out), "--no-baseline"])
    assert rc == 0
    report = json.loads(out.read_text())
    assert report["ok"] is True
    names = [c["name"] for c in report["cells"]]
    assert "plan-cache" in names and "xla/scan/fp32" in names


@pytest.mark.slow
def test_sharded_analyze_and_lint_subprocess():
    """Sharded (plain + resumable) programs on 2 forced host devices:
    lint comes back clean and analyze's collective bytes match the
    psum_bytes_per_sweep oracle exactly."""
    code = textwrap.dedent(
        """
        import numpy as np
        from repro.core.distributed import psum_bytes_per_sweep
        from repro.sparse.generators import random_sparse_tensor
        from repro.tucker import ShardSpec, SnapshotSpec, TuckerSpec
        from repro.tucker.planning import TuckerPlan

        shape, ranks = (12, 10, 8), (3, 3, 2)
        coo = random_sparse_tensor(shape, 0.08, seed=0)
        base = dict(shape=shape, ranks=ranks, method="gram", engine="xla",
                    n_iter=3, shard=ShardSpec(num_devices=2))

        plan = TuckerPlan(TuckerSpec(**base))
        assert plan.lint(coo) == [], plan.lint(coo)
        a = plan.analyze(coo)
        assert a["program"] == "sharded"
        per_sweep = psum_bytes_per_sweep(shape, ranks)
        assert a["collective_bytes_per_sweep"] == per_sweep, a
        assert a["collective_bytes"] == per_sweep * 3, a

        plan = TuckerPlan(TuckerSpec(
            snapshot=SnapshotSpec(every_n_sweeps=2, directory="/tmp/lint-snap"),
            **base))
        assert plan.lint(coo) == [], plan.lint(coo)
        a = plan.analyze(coo)
        assert a["program"] == "sharded-segment"
        assert a["n_sweeps_traced"] == 2
        assert a["collective_bytes"] == per_sweep * 2, a
        print("sharded lint/analyze OK")
        """
    )
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True, env=env
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "sharded lint/analyze OK" in proc.stdout
