"""Per-kernel allclose vs ref.py oracles: shape/dtype sweeps (deliverable c)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.coo import SparseCOO
from repro.kernels import ops, ref
from repro.kernels.kron_kernel import build_scatter_plan, scatter_rows_pallas
from repro.sparse.generators import random_sparse_tensor

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "l,i3,r3", [(1024, 32, 32), (1024, 64, 32), (1024, 128, 32),
                (1024, 256, 32), (100, 300, 17), (8, 8, 8)]
)
def test_ttm_kernel_sweep(l, i3, r3, dtype):
    """Paper Table III shapes (R1R2=1024, I3 in 32..256) + odd shapes."""
    y = RNG.standard_normal((l, i3)).astype(np.float32)
    u = RNG.standard_normal((r3, i3)).astype(np.float32)
    ya = jnp.asarray(y, dtype=dtype)
    ua = jnp.asarray(u, dtype=dtype)
    got = np.asarray(ops.ttm(ya, ua))
    want = np.asarray(ref.ttm_ref(ya.astype(jnp.float32), ua.astype(jnp.float32)))
    tol = 5e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * np.abs(want).max())


@pytest.mark.parametrize(
    "n,ra,rb", [(100, 32, 32), (100, 64, 64), (100, 128, 128), (50, 256, 256),
                (7, 5, 3)]
)
def test_kron_kernel_sweep(n, ra, rb):
    """Paper Table IV shapes (rank 32..256) + odd shapes."""
    a = RNG.standard_normal((n, ra)).astype(np.float32)
    b = RNG.standard_normal((n, rb)).astype(np.float32)
    v = RNG.standard_normal((n,)).astype(np.float32)
    got = np.asarray(ops.kron_contrib(jnp.asarray(a), jnp.asarray(b), jnp.asarray(v)))
    want = np.asarray(ref.kron_contrib_ref(a, b, v))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("n_rows,nnz", [(64, 200), (300, 50), (128, 128), (1, 5)])
def test_scatter_kernel(n_rows, nnz):
    rows = RNG.integers(0, n_rows, size=nnz).astype(np.int32)
    contrib = RNG.standard_normal((nnz, 48)).astype(np.float32)
    plan = build_scatter_plan(rows, n_rows, bn=32, bi=32)
    contrib_perm = contrib[plan.order] * plan.valid[:, None]
    got = np.asarray(
        scatter_rows_pallas(jnp.asarray(contrib_perm), plan, n_rows)
    )
    want = np.asarray(ref.scatter_rows_ref(jnp.asarray(contrib), jnp.asarray(rows), n_rows))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_full_sparse_chain_kernel_vs_core(mode):
    coo = random_sparse_tensor((40, 30, 20), 0.02, seed=2)
    fs = [jnp.asarray(RNG.standard_normal((s, r)).astype(np.float32))
          for s, r in zip(coo.shape, (6, 5, 4))]
    got = np.asarray(ops.sparse_ttm_chain_kernel(coo, fs, mode))
    want = np.asarray(
        ref.sparse_ttm_chain_ref(coo.indices, coo.values, fs, mode, coo.shape[mode])
    )
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize(
    "b,h,kvh,s,t,d,bq,bk",
    [
        (2, 4, 2, 128, 128, 64, 64, 64),
        (1, 8, 4, 64, 256, 32, 32, 64),   # decode-style: t > s
        (2, 2, 2, 100, 100, 64, 32, 32),  # non-multiple seq
        (1, 4, 1, 128, 128, 128, 128, 128),  # MQA
    ],
)
def test_flash_attention_sweep(b, h, kvh, s, t, d, bq, bk):
    q = RNG.standard_normal((b, h, s, d)).astype(np.float32)
    k = RNG.standard_normal((b, kvh, t, d)).astype(np.float32)
    v = RNG.standard_normal((b, kvh, t, d)).astype(np.float32)
    got = np.asarray(ops.flash_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), block_q=bq, block_k=bk))
    want = np.asarray(ref.flash_attention_ref(q, k, v, causal=True))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 4, 64, 64)), dtype=jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 64, 64)), dtype=jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 64, 64)), dtype=jnp.bfloat16)
    got = np.asarray(ops.flash_attention(q, k, v, block_q=32, block_k=32)).astype(np.float32)
    want = np.asarray(ref.flash_attention_ref(q, k, v, causal=True)).astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=0.05, atol=0.05)


@pytest.mark.parametrize("bh,c,l,p,n", [(2, 3, 64, 32, 16), (1, 1, 128, 64, 32)])
def test_ssd_chunk_kernel(bh, c, l, p, n):
    x = RNG.standard_normal((bh, c, l, p)).astype(np.float32)
    acs = np.cumsum(-np.abs(RNG.standard_normal((bh, c, l))) * 0.1, axis=-1).astype(np.float32)
    bm = RNG.standard_normal((bh, c, l, n)).astype(np.float32)
    cm = RNG.standard_normal((bh, c, l, n)).astype(np.float32)
    y, s = ops.ssd_chunk(jnp.asarray(x), jnp.asarray(acs), jnp.asarray(bm), jnp.asarray(cm))
    for i in range(bh):
        for j in range(c):
            yr, sr = ref.ssd_chunk_ref(x[i, j], acs[i, j], bm[i, j], cm[i, j])
            np.testing.assert_allclose(np.asarray(y[i, j]), np.asarray(yr), rtol=2e-4, atol=2e-4)
            np.testing.assert_allclose(np.asarray(s[i, j]), np.asarray(sr), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_matches_model_mixer():
    """The Pallas SSD kernel and the model's jnp SSD produce the same
    within-chunk output (same math, two lowerings)."""
    from repro.models.mamba2 import ssd_mixer
    from repro.configs import get_config
    import dataclasses
    cfg = get_config("mamba2-1.3b", smoke=True)
    # single chunk so inter-chunk recurrence is identity
    b, s = 1, cfg.ssm_chunk
    d = cfg.d_model
    x = jnp.asarray(RNG.standard_normal((b, s, d)).astype(np.float32))
    from repro.models.model import init_params
    params = init_params(dataclasses.replace(cfg, dtype="float32"), jax.random.PRNGKey(0))
    p = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
    y_model, _ = ssd_mixer(cfg, p, x)
    assert not bool(jnp.any(jnp.isnan(y_model)))


# ---------------------------------------------------------------------------
# Fused Kron→scatter→TTM megakernel (ISSUE 7): the core update G = U^T Y_(n)
# without materializing Y_(n).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", [0, 1, 2])
def test_fused_core_megakernel_vs_oracle(mode):
    from repro.core.engine import make_engine

    coo = random_sparse_tensor((24, 18, 16), 0.03, seed=5)
    fs = [jnp.asarray(RNG.standard_normal((s, r)).astype(np.float32))
          for s, r in zip(coo.shape, (5, 4, 3))]
    eng = make_engine("pallas", fuse_core=True, interpret=True)
    sched = eng.device_schedule(coo, mode)
    got = np.asarray(ops.sparse_ttm_core_device(
        coo.indices, coo.values, tuple(fs), mode, sched,
        shape=coo.shape, interpret=True,
    ))
    y = np.asarray(ref.sparse_ttm_chain_ref(
        coo.indices, coo.values, fs, mode, coo.shape[mode]
    ))
    want = np.asarray(fs[mode]).T @ y
    assert got.shape == want.shape
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_fused_core_megakernel_empty_tensor():
    from repro.core.engine import make_engine

    coo = SparseCOO(jnp.zeros((0, 3), jnp.int32), jnp.zeros((0,)), (8, 6, 4))
    fs = [jnp.asarray(RNG.standard_normal((s, 2)).astype(np.float32))
          for s in coo.shape]
    eng = make_engine("pallas", fuse_core=True, interpret=True)
    sched = eng.device_schedule(coo, 2)
    got = np.asarray(ops.sparse_ttm_core_device(
        coo.indices, coo.values, tuple(fs), 2, sched,
        shape=coo.shape, interpret=True,
    ))
    assert got.shape == (2, 4) and not got.any()


def test_fused_core_megakernel_bf16_close_to_fp32():
    from repro.core.engine import make_engine

    coo = random_sparse_tensor((20, 16, 32), 0.04, seed=6)
    fs = [jnp.asarray(RNG.standard_normal((s, r)).astype(np.float32))
          for s, r in zip(coo.shape, (4, 3, 5))]
    eng = make_engine("pallas", fuse_core=True, interpret=True)
    sched = eng.device_schedule(coo, 2)
    kw = dict(shape=coo.shape, interpret=True)
    f32 = np.asarray(ops.sparse_ttm_core_device(
        coo.indices, coo.values, tuple(fs), 2, sched, **kw))
    b16 = np.asarray(ops.sparse_ttm_core_device(
        coo.indices, coo.values, tuple(fs), 2, sched,
        precision="bf16_fp32acc", **kw))
    assert b16.dtype == np.float32  # f32 accumulators all the way out
    np.testing.assert_allclose(b16, f32, rtol=3e-2, atol=3e-2 * np.abs(f32).max())


def test_hooi_fuse_core_on_off_parity():
    """Full HOOI with the fused core update matches the split path — the
    megakernel only changes WHERE the contraction happens, not the math."""
    from repro import tucker
    from repro.core.engine import make_engine

    coo = random_sparse_tensor((16, 12, 10), 0.05, seed=7)
    spec = tucker.TuckerSpec(shape=coo.shape, ranks=(3, 3, 2),
                             method="gram", n_iter=3, engine="pallas")
    split = tucker.plan(spec, engine=make_engine("pallas", fuse_core=False))(coo)
    fused = tucker.plan(spec, engine=make_engine("pallas", fuse_core=True))(coo)
    np.testing.assert_allclose(np.asarray(fused.core), np.asarray(split.core),
                               rtol=1e-5, atol=1e-5)
    assert abs(fused.rel_error - split.rel_error) < 1e-6
