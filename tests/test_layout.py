"""Property tests for the sparse invariants (hypothesis; optional dev dep).

The layout transforms (``sort_by_mode``, ``pad_to``, ``build_mode_layout``)
and the linearized unfolding index must all be *value-preserving*: whatever
permutation/padding the schedule applies, ``to_dense()`` — and therefore
every contraction — is unchanged. And the sparse TTM chain must equal the
dense ``ttm_chain`` oracle on arbitrary COO tensors, duplicates included.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.coo import SparseCOO, unfold_dense
from repro.core.kron import sparse_ttm_chain
from repro.core.ttm import ttm_chain
from repro.sparse.layout import build_mode_layout

SETTINGS = settings(max_examples=25, deadline=None)


@st.composite
def coo_tensors(draw, max_ndim=3, max_side=6, max_nnz=20):
    ndim = draw(st.integers(2, max_ndim))
    shape = tuple(draw(st.integers(1, max_side)) for _ in range(ndim))
    nnz = draw(st.integers(0, max_nnz))
    idx = np.array(
        [[draw(st.integers(0, s - 1)) for s in shape] for _ in range(nnz)],
        dtype=np.int32,
    ).reshape(nnz, ndim)
    vals = np.array(
        [draw(st.floats(-4, 4, allow_nan=False, width=32)) for _ in range(nnz)],
        dtype=np.float32,
    )
    return SparseCOO.from_parts(idx, vals, shape)


@SETTINGS
@given(coo=coo_tensors(), data=st.data())
def test_sort_by_mode_preserves_dense(coo, data):
    mode = data.draw(st.integers(0, coo.ndim - 1))
    want = np.asarray(coo.to_dense())
    got = np.asarray(coo.sort_by_mode(mode).to_dense())
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), extra=st.integers(0, 17))
def test_pad_to_preserves_dense(coo, extra):
    want = np.asarray(coo.to_dense())
    got = np.asarray(coo.pad_to(coo.nnz + extra).to_dense())
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), data=st.data())
def test_linearized_index_matches_unfolding(coo, data):
    """Scattering values at (i_mode, linearized col) rebuilds unfold(dense)."""
    mode = data.draw(st.integers(0, coo.ndim - 1))
    col = coo.linearized_index(mode)
    rest = int(np.prod([s for t, s in enumerate(coo.shape) if t != mode]))
    mat = np.zeros((coo.shape[mode], rest), dtype=np.float32)
    np.add.at(mat, (np.asarray(coo.indices)[:, mode], col), np.asarray(coo.values))
    want = np.asarray(unfold_dense(coo.to_dense(), mode))
    np.testing.assert_allclose(mat, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), data=st.data(), bn=st.sampled_from([4, 8, 32]),
       bi=st.sampled_from([4, 16]))
def test_mode_layout_streams_each_nonzero_once(coo, data, bn, bi):
    """The engine schedule is a permutation + padding: replaying it through a
    plain scatter reproduces to_dense()'s mode unfolding of the values."""
    mode = data.draw(st.integers(0, coo.ndim - 1))
    layout = build_mode_layout(coo, mode, bn=bn, bi=bi)
    real = layout.order[layout.valid > 0]
    assert sorted(real.tolist()) == list(range(coo.nnz))
    # replay: padded slots carry valid=0 so they add nothing
    rows_global = layout.blkmap.repeat(bn) * bi + layout.rel_row
    vals_src = np.asarray(coo.values)
    vals = (
        vals_src[layout.order] if coo.nnz else np.zeros(layout.order.shape, np.float32)
    ) * layout.valid
    acc = np.zeros((layout.n_row_blocks * bi,), dtype=np.float32)
    np.add.at(acc, rows_global, vals)
    want = np.zeros_like(acc)
    np.add.at(want, np.asarray(coo.indices)[:, mode], np.asarray(coo.values))
    np.testing.assert_allclose(acc, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), data=st.data(), seed=st.integers(0, 2**31 - 1))
def test_sparse_ttm_chain_matches_dense_oracle(coo, data, seed):
    mode = data.draw(st.integers(0, coo.ndim - 1))
    rng = np.random.default_rng(seed)
    ranks = [min(3, s) for s in coo.shape]
    factors = [
        jnp.asarray(rng.standard_normal((s, r)).astype(np.float32))
        for s, r in zip(coo.shape, ranks)
    ]
    got = np.asarray(sparse_ttm_chain(coo, factors, mode))
    want = np.asarray(
        unfold_dense(ttm_chain(coo.to_dense(), factors, skip=mode, transpose=True), mode)
    )
    scale = np.abs(want).max() + 1e-6
    np.testing.assert_allclose(got / scale, want / scale, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Shard padding (the sharded pipeline's even-split layer).
# ---------------------------------------------------------------------------

from repro.sparse.layout import shard_pad_nnz  # noqa: E402


@SETTINGS
@given(nnz=st.integers(0, 10_000), n_shards=st.integers(1, 64))
def test_shard_pad_nnz_is_minimal_multiple(nnz, n_shards):
    """The padded nnz is the MINIMAL multiple of the shard count that holds
    every nonzero (and is never zero: each shard owns at least one slot)."""
    p = shard_pad_nnz(nnz, n_shards)
    assert p % n_shards == 0 and p >= nnz and p >= n_shards
    # minimality: one shard-width less would drop nonzeros (or hit zero)
    assert p - n_shards < max(nnz, 1)
    # idempotent: padding an already-even count is the identity
    assert shard_pad_nnz(p, n_shards) == p


@SETTINGS
@given(coo=coo_tensors(), data=st.data(), n_shards=st.integers(1, 8),
       seed=st.integers(0, 2**31 - 1))
def test_shard_padding_preserves_unfolding_product(coo, data, n_shards, seed):
    """Explicit-zero padding to the shard multiple never changes any mode-n
    unfolding product: the padded tensor's sparse TTM chain equals the
    unpadded one's, for every mode."""
    mode = data.draw(st.integers(0, coo.ndim - 1))
    rng = np.random.default_rng(seed)
    factors = [
        jnp.asarray(rng.standard_normal((s, min(2, s))).astype(np.float32))
        for s in coo.shape
    ]
    padded = coo.pad_to(shard_pad_nnz(coo.nnz, n_shards))
    assert padded.nnz % n_shards == 0
    got = np.asarray(sparse_ttm_chain(padded, factors, mode))
    want = np.asarray(sparse_ttm_chain(coo, factors, mode))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# nnz bucketing + batch padding (the serving plane's shape-stability layer).
# ---------------------------------------------------------------------------

from repro.sparse.layout import bucket_nnz, pad_coo_batch  # noqa: E402


@SETTINGS
@given(nnz=st.integers(0, 5_000), n_shards=st.integers(1, 16),
       base=st.integers(1, 256))
def test_shard_pad_round_trips_with_bucket_nnz(nnz, n_shards, base):
    """The serving bucket grid and the shard grid compose stably: sharding a
    bucket boundary then re-applying either padding is a fixpoint, and the
    composition never drops below either grid alone."""
    b = bucket_nnz(nnz, base=base)
    p = shard_pad_nnz(b, n_shards)
    assert p >= b >= nnz
    assert shard_pad_nnz(p, n_shards) == p  # fixpoint under re-sharding
    assert bucket_nnz(p, base=base) >= p  # re-bucketing never shrinks it
    # and when the shard count divides the bucket boundary, sharding is free
    if b % n_shards == 0:
        assert p == b


@SETTINGS
@given(nnz=st.integers(0, 10_000), base=st.integers(1, 512),
       growth=st.floats(1.1, 4.0, allow_nan=False))
def test_bucket_nnz_properties(nnz, base, growth):
    b = bucket_nnz(nnz, base=base, growth=growth)
    assert b >= nnz and b >= base  # never drops nonzeros, never sub-base
    assert bucket_nnz(b, base=base, growth=growth) == b  # boundaries are fixpoints
    if nnz > base:
        # minimality: the next-smaller grid point is strictly below nnz
        prev = base
        while True:
            nxt = int(np.ceil(prev * growth))
            if nxt >= b:
                break
            prev = nxt
        assert prev < nnz


@SETTINGS
@given(nnz_a=st.integers(0, 500), nnz_b=st.integers(0, 500))
def test_bucket_nnz_monotone(nnz_a, nnz_b):
    lo, hi = sorted((nnz_a, nnz_b))
    assert bucket_nnz(lo) <= bucket_nnz(hi)


@st.composite
def same_shape_coo_batches(draw, max_ndim=3, max_side=5, max_nnz=12, max_k=4):
    ndim = draw(st.integers(2, max_ndim))
    shape = tuple(draw(st.integers(1, max_side)) for _ in range(ndim))
    coos = []
    for _ in range(draw(st.integers(1, max_k))):
        nnz = draw(st.integers(0, max_nnz))
        idx = np.array(
            [[draw(st.integers(0, s - 1)) for s in shape] for _ in range(nnz)],
            dtype=np.int32,
        ).reshape(nnz, ndim)
        vals = np.array(
            [draw(st.floats(-4, 4, allow_nan=False, width=32))
             for _ in range(nnz)],
            dtype=np.float32,
        )
        coos.append(SparseCOO.from_parts(idx, vals, shape))
    return coos


@SETTINGS
@given(coos=same_shape_coo_batches(), extra=st.integers(0, 9))
def test_pad_coo_batch_preserves_each_member_dense(coos, extra):
    shape = coos[0].shape
    nnz_max = max(c.nnz for c in coos)
    idx, val = pad_coo_batch(coos, target_nnz=nnz_max + extra)
    assert idx.shape == (len(coos), nnz_max + extra, len(shape))
    for k, c in enumerate(coos):
        rebuilt = SparseCOO.from_parts(idx[k], val[k], shape)
        np.testing.assert_allclose(
            np.asarray(rebuilt.to_dense()), np.asarray(c.to_dense()),
            rtol=1e-6, atol=1e-6,
        )


# Ragged-nnz batched-decompose parity (ISSUE 4 satellite). The spec is FIXED
# and every batch pads to one bucket boundary so hypothesis explores data, not
# compile-cache keys: the whole property reuses two compiled programs.
_PARITY_SHAPE = (6, 5, 4)
_PARITY_BUCKET = 32


@st.composite
def ragged_coo_batches(draw, k=3, max_nnz=24):
    coos = []
    for _ in range(k):
        nnz = draw(st.integers(1, max_nnz))
        idx = np.array(
            [[draw(st.integers(0, s - 1)) for s in _PARITY_SHAPE]
             for _ in range(nnz)],
            dtype=np.int32,
        ).reshape(nnz, len(_PARITY_SHAPE))
        # bounded away from 0 so no member is an (undefined) all-zero tensor
        vals = np.array(
            [draw(st.floats(0.1, 4, allow_nan=False, width=32))
             * (-1 if draw(st.booleans()) else 1) for _ in range(nnz)],
            dtype=np.float32,
        )
        coos.append(SparseCOO.from_parts(idx, vals, _PARITY_SHAPE))
    return coos


@settings(max_examples=10, deadline=None)
@given(coos=ragged_coo_batches())
def test_batched_padded_decompose_matches_per_tensor(coos):
    """The serving contract: batched-and-padded results are allclose to
    per-tensor decompose across ragged nnz."""
    from repro import tucker

    spec = tucker.TuckerSpec(shape=_PARITY_SHAPE, ranks=(2, 2, 2),
                             method="gram", n_iter=2)
    plan = tucker.plan(spec)
    got = plan.batch(coos, pad_nnz_to=_PARITY_BUCKET)
    for c, g in zip(coos, got):
        # sequential reference on the SAME padded nnz shape (one compiled
        # per-tensor program for the whole property, not one per drawn nnz)
        ref = plan(c.pad_to(_PARITY_BUCKET))
        np.testing.assert_allclose(np.asarray(g.core), np.asarray(ref.core),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(g.fit_history, ref.fit_history, atol=1e-5)
        for fg, fr in zip(g.factors, ref.factors):
            np.testing.assert_allclose(np.asarray(fg), np.asarray(fr),
                                       rtol=1e-4, atol=1e-4)
