"""Property tests for the sparse invariants (hypothesis; optional dev dep).

The layout transforms (``sort_by_mode``, ``pad_to``, ``build_mode_layout``)
and the linearized unfolding index must all be *value-preserving*: whatever
permutation/padding the schedule applies, ``to_dense()`` — and therefore
every contraction — is unchanged. And the sparse TTM chain must equal the
dense ``ttm_chain`` oracle on arbitrary COO tensors, duplicates included.
"""
import numpy as np
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core.coo import SparseCOO, unfold_dense
from repro.core.kron import sparse_ttm_chain
from repro.core.ttm import ttm_chain
from repro.sparse.layout import build_mode_layout

SETTINGS = settings(max_examples=25, deadline=None)


@st.composite
def coo_tensors(draw, max_ndim=3, max_side=6, max_nnz=20):
    ndim = draw(st.integers(2, max_ndim))
    shape = tuple(draw(st.integers(1, max_side)) for _ in range(ndim))
    nnz = draw(st.integers(0, max_nnz))
    idx = np.array(
        [[draw(st.integers(0, s - 1)) for s in shape] for _ in range(nnz)],
        dtype=np.int32,
    ).reshape(nnz, ndim)
    vals = np.array(
        [draw(st.floats(-4, 4, allow_nan=False, width=32)) for _ in range(nnz)],
        dtype=np.float32,
    )
    return SparseCOO.from_parts(idx, vals, shape)


@SETTINGS
@given(coo=coo_tensors(), data=st.data())
def test_sort_by_mode_preserves_dense(coo, data):
    mode = data.draw(st.integers(0, coo.ndim - 1))
    want = np.asarray(coo.to_dense())
    got = np.asarray(coo.sort_by_mode(mode).to_dense())
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), extra=st.integers(0, 17))
def test_pad_to_preserves_dense(coo, extra):
    want = np.asarray(coo.to_dense())
    got = np.asarray(coo.pad_to(coo.nnz + extra).to_dense())
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), data=st.data())
def test_linearized_index_matches_unfolding(coo, data):
    """Scattering values at (i_mode, linearized col) rebuilds unfold(dense)."""
    mode = data.draw(st.integers(0, coo.ndim - 1))
    col = coo.linearized_index(mode)
    rest = int(np.prod([s for t, s in enumerate(coo.shape) if t != mode]))
    mat = np.zeros((coo.shape[mode], rest), dtype=np.float32)
    np.add.at(mat, (np.asarray(coo.indices)[:, mode], col), np.asarray(coo.values))
    want = np.asarray(unfold_dense(coo.to_dense(), mode))
    np.testing.assert_allclose(mat, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), data=st.data(), bn=st.sampled_from([4, 8, 32]),
       bi=st.sampled_from([4, 16]))
def test_mode_layout_streams_each_nonzero_once(coo, data, bn, bi):
    """The engine schedule is a permutation + padding: replaying it through a
    plain scatter reproduces to_dense()'s mode unfolding of the values."""
    mode = data.draw(st.integers(0, coo.ndim - 1))
    layout = build_mode_layout(coo, mode, bn=bn, bi=bi)
    real = layout.order[layout.valid > 0]
    assert sorted(real.tolist()) == list(range(coo.nnz))
    # replay: padded slots carry valid=0 so they add nothing
    rows_global = layout.blkmap.repeat(bn) * bi + layout.rel_row
    vals_src = np.asarray(coo.values)
    vals = (
        vals_src[layout.order] if coo.nnz else np.zeros(layout.order.shape, np.float32)
    ) * layout.valid
    acc = np.zeros((layout.n_row_blocks * bi,), dtype=np.float32)
    np.add.at(acc, rows_global, vals)
    want = np.zeros_like(acc)
    np.add.at(want, np.asarray(coo.indices)[:, mode], np.asarray(coo.values))
    np.testing.assert_allclose(acc, want, rtol=1e-6, atol=1e-6)


@SETTINGS
@given(coo=coo_tensors(), data=st.data(), seed=st.integers(0, 2**31 - 1))
def test_sparse_ttm_chain_matches_dense_oracle(coo, data, seed):
    mode = data.draw(st.integers(0, coo.ndim - 1))
    rng = np.random.default_rng(seed)
    ranks = [min(3, s) for s in coo.shape]
    factors = [
        jnp.asarray(rng.standard_normal((s, r)).astype(np.float32))
        for s, r in zip(coo.shape, ranks)
    ]
    got = np.asarray(sparse_ttm_chain(coo, factors, mode))
    want = np.asarray(
        unfold_dense(ttm_chain(coo.to_dense(), factors, skip=mode, transpose=True), mode)
    )
    scale = np.abs(want).max() + 1e-6
    np.testing.assert_allclose(got / scale, want / scale, rtol=1e-5, atol=1e-5)
